"""repro.faults — fault injection over the gossip fabric (tier-1).

The contracts held here:

  * a zero-rate FaultSpec is BIT-identical to a fault-free run — both
    engines, delay in {0, 2}, sparse and dense mixer forms, noise on (the
    same property benchmarks/bench_faults.py gates in CI as
    ``zero_fault_identical``);
  * fault-masked + self-healed mixing matrices stay row-stochastic and
    non-negative at every round, for any seed and rate — and symmetric
    inputs stay symmetric under link faults (one Bernoulli per undirected
    link);
  * crashed nodes freeze their theta, spend no eps (participation-masked
    accounting) and rejoin from their last state;
  * connectivity dips while a transient partition is up and returns to
    1.0 once it heals; degradation()/rounds_to_recover summarize it;
  * the seed-vmapped `run_batch` path matches sequential runs under
    faults (the fault pattern is scenario-seeded, not run-seeded);
  * serving: requests past their deadline shed with reason 'timeout'
    (vs 'full'), and an injected trainer crash auto-restarts from the
    last checkpoint bit-identically.

Multi-device fault x shard coverage lives in tests/test_faults_shard.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import RunSpec, run
from repro.api.mixers import MIXERS
from repro.api.runner import run_batch
from repro.core.privacy import PrivacyAccountant
from repro.faults import (FAULTS, FaultSpec, FaultySparseMixer, degradation,
                          rounds_to_recover, wrap_mixer)

FIELDS = ("final_w", "loss", "correct", "w_bar_loss", "sparsity")


def spec(**kw):
    base = dict(nodes=6, dim=8, horizon=10, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 3},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)


def _run(s, **kw):
    base = dict(chunk_rounds=4, compute_regret=False, warmup=False)
    base.update(kw)
    return run(s, **base)


def assert_identical(a, b, what):
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{what}: field {f} diverged")


# -- zero-rate bit-identity ---------------------------------------------------

@pytest.mark.parametrize("engine", ["sim", "dist"])
@pytest.mark.parametrize("delay", [0, 2])
def test_zero_fault_bit_identical_sparse(engine, delay):
    clean = _run(spec(delay=delay), engine=engine)
    zero = _run(spec(delay=delay, faults="links",
                     faults_options={"link_rate": 0.0}), engine=engine)
    assert_identical(clean, zero, f"{engine}/delay={delay}")
    assert zero.connectivity is not None
    np.testing.assert_array_equal(zero.connectivity,
                                  np.ones(clean.rounds, np.float32))


def test_zero_fault_bit_identical_dense():
    for engine in ("sim", "dist"):
        clean = _run(spec(mixer="dense"), engine=engine)
        zero = _run(spec(mixer="dense", faults="none"), engine=engine)
        assert_identical(clean, zero, f"dense/{engine}")


# -- effective-matrix properties ----------------------------------------------

def _effective_matrix(mixer, t):
    """A_eff(t) via apply on the identity: column j is A @ e_j stacked."""
    return np.asarray(mixer.apply(jnp.eye(mixer.m, dtype=jnp.float32), t))


@pytest.mark.parametrize("mixer_name", ["sparse", "dense"])
@pytest.mark.parametrize("rate", [0.3, 0.9])
@pytest.mark.parametrize("fseed", [0, 3])
def test_link_faulted_matrix_row_stochastic_and_symmetric(
        mixer_name, rate, fseed):
    s = spec(mixer=mixer_name, faults="links",
             faults_options={"link_rate": rate, "seed": fseed})
    mixer = s.resolve_mixer()
    for t in (0, 1, 7):
        A = _effective_matrix(mixer, t)
        assert (A >= 0.0).all(), f"t={t}: negative weight"
        np.testing.assert_allclose(A.sum(axis=1), 1.0, atol=1e-6,
                                   err_msg=f"t={t}: rows not stochastic")
        # ring weights are symmetric and both directions of a link share
        # one Bernoulli coin, so the healed matrix stays symmetric
        np.testing.assert_allclose(A, A.T, atol=1e-6,
                                   err_msg=f"t={t}: symmetry broken")


def test_crash_and_partition_matrix_stays_row_stochastic():
    s = spec(faults=FaultSpec(link_rate=0.2, crashes=((1, 2, 6),),
                              partitions=((3, 6, 3),), seed=5))
    mixer = s.resolve_mixer()
    for t in range(8):
        A = _effective_matrix(mixer, t)
        assert (A >= 0.0).all()
        np.testing.assert_allclose(A.sum(axis=1), 1.0, atol=1e-6)
    # while node 1 is crashed its outgoing weight heals onto neighbors'
    # self-loops: column 1 carries only its own self-weight
    A = _effective_matrix(mixer, 3)
    off = A[:, 1].copy()
    off[1] = 0.0
    assert off.max() == 0.0


# -- crash semantics ----------------------------------------------------------

def test_crashed_node_freezes_and_rejoins():
    crash = FaultSpec(crashes=((2, 3, 7),))
    thetas = {}

    def grab(round_end, eng_state, accountant):
        thetas[round_end] = np.asarray(eng_state.theta)
        return False

    _run(spec(faults=crash), chunk_rounds=1, on_chunk=grab)
    for t in range(3, 7):       # frozen through the window...
        np.testing.assert_array_equal(thetas[t + 1][2], thetas[3][2])
    assert not np.array_equal(thetas[8][2], thetas[3][2])  # ...then rejoins


def test_crashed_rounds_spend_no_eps():
    crash = FaultSpec(crashes=((2, 3, 7),))
    res = _run(spec(faults=crash))
    part = res.privacy["participated_rounds"]
    assert part == [10, 10, 6, 10, 10, 10]
    assert res.privacy["eps_per_node_max"] == res.privacy["eps_per_round"]


def test_accountant_participation_sequential_composition():
    acc = PrivacyAccountant(eps_per_round=0.5, disjoint_streams=False)
    acc.step(4)
    acc.step(4, participation=np.array([4, 1, 0]))
    acc.step(2)
    assert acc.node_rounds.tolist() == [10, 7, 6]
    np.testing.assert_allclose(acc.per_node_guarantee(), [5.0, 3.5, 3.0])
    with pytest.raises(ValueError, match="participation"):
        acc.step(2, participation=np.array([3, 0, 0]))


# -- degradation metrics ------------------------------------------------------

def test_partition_connectivity_dips_then_recovers():
    part = FaultSpec(partitions=((3, 6, 3),))
    clean = _run(spec())
    faulty = _run(spec(faults=part))
    conn = faulty.connectivity
    assert conn[:3].min() == 1.0 and conn[6:].min() == 1.0
    assert conn[3:6].max() < 1.0
    deg = degradation(clean, faulty)
    assert deg["min_connectivity"] < 1.0
    assert deg["min_connectivity"] <= deg["mean_connectivity"] < 1.0
    assert np.isfinite(deg["loss_gap"])
    r = rounds_to_recover(clean.correct.mean(axis=1),
                          faulty.correct.mean(axis=1),
                          heal_round=6, tol=0.5, window=2)
    assert r >= 0


def test_rounds_to_recover_never_and_validation():
    clean = np.zeros(8)
    assert rounds_to_recover(clean, np.ones(8), heal_round=2, tol=0.1) == -1
    with pytest.raises(ValueError):
        rounds_to_recover(clean, np.ones(5), heal_round=2)


# -- spec / registry surfaces -------------------------------------------------

def test_faults_registry_and_validation():
    assert sorted(FAULTS.names()) == ["crash", "dcn", "links", "none",
                                      "partition"]
    assert FAULTS.build("none", {}).is_zero
    with pytest.raises(ValueError, match="link_rate"):
        FaultSpec(link_rate=1.5)
    with pytest.raises(ValueError, match="horizon"):
        FaultSpec(crash_rate=0.5).compile(m=4)          # seeded crashes
    with pytest.raises(ValueError, match="delay_dist"):
        spec(faults="links", delay=2, delay_dist="uniform").resolve_mixer()


def test_wrap_mixer_surfaces():
    sched = FaultSpec(link_rate=0.1).compile(m=6)
    ring = MIXERS.build("ring", {}, m=6, seed=0)        # RingRollMixer
    assert isinstance(wrap_mixer(ring, sched), FaultySparseMixer)
    disconnected = MIXERS.build("disconnected", {}, m=6, seed=0)
    with pytest.raises(ValueError, match="[Dd]isconnected"):
        wrap_mixer(disconnected, sched)
    het = spec(mixer="ring", mixer_options={}, delay=2,
               delay_dist="uniform").resolve_mixer()
    with pytest.raises(ValueError, match="straggler"):
        wrap_mixer(het, sched)


def test_straggler_outgoing_broadcasts_arrive_late():
    # node 0's egress is 1 round late; the faulty mixer widens the ring
    lag = FaultSpec(stragglers=((0, 1),))
    s = spec(faults=lag, delay=1)
    mixer = s.resolve_mixer()
    assert mixer.delay == 2 and mixer.base_delay == 1
    res = _run(s)
    base = _run(spec(delay=1))
    assert not np.array_equal(res.final_w, base.final_w)


# -- seed-vmapped batch under faults ------------------------------------------

def test_run_batch_matches_sequential_under_faults():
    s = spec(faults=FaultSpec(link_rate=0.2, crashes=((1, 2, 6),), seed=9))
    batch = run_batch(s, [0, 1, 2], chunk_rounds=4, compute_regret=False,
                      warmup=False)
    for i, sd in enumerate((0, 1, 2)):
        seq = _run(s.replace(seed=sd))
        assert_identical(batch[i], seq, f"seed={sd} batch vs sequential")
        np.testing.assert_array_equal(batch[i].connectivity, seq.connectivity)
        assert (batch[i].privacy["participated_rounds"]
                == seq.privacy["participated_rounds"])


# -- serving under faults -----------------------------------------------------

def test_request_deadline_sheds_with_timeout_reason():
    from repro.serve import ServeConfig, ServeService
    svc = ServeService(ServeConfig(spec=spec(stream="bursty",
                                             stream_options={}),
                                   train=False, warmup=False, max_age_s=0.0,
                                   max_wait_ms=0.5)).start()
    r = svc.submit([1.0] * 8, node=0)
    r.wait(10.0)
    svc.stop()
    assert (r.status, r.shed_reason) == ("shed", "timeout")
    summary = svc.stats()["admission"]
    assert summary["shed_reasons"] == {"timeout": 1}
    assert summary["shed"] == 1


def test_queue_full_sheds_with_full_reason():
    from repro.serve import ServeConfig, ServeService
    svc = ServeService(ServeConfig(spec=spec(stream="bursty",
                                             stream_options={}),
                                   train=False, warmup=False,
                                   queue_capacity=1, max_wait_ms=0.5))
    # not started: the batcher never drains, so the 2nd submit finds no room
    svc.state.publish_initial()
    svc.submit([1.0] * 8, node=0)
    shed = svc.submit([1.0] * 8, node=0)
    assert (shed.status, shed.shed_reason) == ("shed", "full")
    assert svc.stats_.summary()["shed_reasons"] == {"full": 1}


def test_trainer_crash_restarts_bit_identically(tmp_path):
    from repro.serve import BackgroundTrainer, ServeState, TrainerCrash
    s = spec(stream="bursty", stream_options={}, horizon=12)
    st = ServeState(s)
    st.publish_initial()
    tr = BackgroundTrainer(s, st, chunk_rounds=4, warmup=False,
                           checkpoint_dir=str(tmp_path), crash_at_round=8)
    tr.run_blocking()
    assert tr.restarts == 1 and tr.round == 12
    clean = _run(s)
    np.testing.assert_array_equal(tr.result.final_w, clean.final_w)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        BackgroundTrainer(s, st, crash_at_round=4)
    assert issubclass(TrainerCrash, RuntimeError)
