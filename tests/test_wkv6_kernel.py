"""WKV6 Pallas kernel vs the pure-jnp recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import wkv6_ref
from repro.kernels.wkv6 import wkv6


def _inputs(B, T, H, K, seed=0):
    key = jax.random.PRNGKey(seed)
    r = jax.random.normal(key, (B, T, H, K)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, K)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, K))
    w = jax.random.normal(jax.random.fold_in(key, 3), (B, T, H, K)) * 0.3
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, K)) * 0.1
    return r, k, v, w, u


def _oracle(r, k, v, w, u):
    B, T, H, K = r.shape
    return jnp.stack([
        jnp.stack([wkv6_ref(r[b, :, h], k[b, :, h], v[b, :, h], w[b, :, h],
                            u[h], jnp.zeros((K, K)))[0] for h in range(H)], axis=1)
        for b in range(B)])


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (48, 16), (100, 32)])
def test_wkv6_kernel_matches_oracle(T, chunk):
    r, k, v, w, u = _inputs(2, T, 2, 8)
    y = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    ref = _oracle(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_wkv6_kernel_state_carries_across_chunks():
    """Same answer whether the sequence is one chunk or many."""
    r, k, v, w, u = _inputs(1, 64, 1, 8, seed=5)
    y1 = wkv6(r, k, v, w, u, chunk=64, interpret=True)
    y2 = wkv6(r, k, v, w, u, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


def test_wkv6_kernel_matches_model_scan():
    """Kernel == the rwkv6 model's _wkv_scan (the production consumer)."""
    from repro.models.rwkv6 import _wkv_scan
    r, k, v, w, u = _inputs(2, 40, 2, 8, seed=7)
    decay = jnp.exp(-jnp.exp(w))
    y_model, _ = _wkv_scan(r, k, v, decay, u,
                           jnp.zeros((2, 2, 8, 8)))
    y_kernel = wkv6(r, k, v, w, u, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-5, atol=1e-5)
