import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunSpec, run
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.algorithm1 import hinge_loss_and_grad


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, tree)
    assert latest_step(d) == 12
    back = restore_checkpoint(d, tree, step=7)
    assert back["params"]["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(back["params"]["w"], np.float32),
                               np.arange(12).reshape(3, 4))
    np.testing.assert_array_equal(np.asarray(back["params"]["b"]), np.ones(4))
    assert int(back["step"]) == 7


def test_checkpoint_latest_of_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None


def _delay_spec(delay: int) -> RunSpec:
    return RunSpec(nodes=4, dim=32, horizon=24, eps=1.0, alpha0=0.5,
                   lam=0.01, delay=delay, stream="social_sparse")


@pytest.mark.parametrize("delay", [0, 2])
def test_gossip_state_roundtrip_bit_identical_continuation(tmp_path, delay):
    """Save GossipState mid-run (incl. the PR-2 history ring), restore, and
    the continuation is bit-identical to the uninterrupted run."""
    spec = _delay_spec(delay)
    gdp = spec.build_distributed()
    stream = spec.resolve_stream()
    xs, ys = stream.chunk(0, 24)

    def rounds(state, t0, t1):
        for t in range(t0, t1):
            w = gdp.primal(state)["w"]
            _, grad = hinge_loss_and_grad(w, xs[t], ys[t])
            state, _ = gdp.update(state, {"w": grad})
        return state

    init = gdp.init({"w": jnp.zeros((4, 32))}, jax.random.PRNGKey(0))
    if delay:
        assert init.history["w"].shape == (delay + 1, 4, 32)
    full = rounds(init, 0, 24)

    mid = rounds(init, 0, 12)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 12, mid)
    restored = jax.tree_util.tree_map(jnp.asarray,
                                      restore_checkpoint(d, mid, step=12))
    # the whole state round-trips exactly: theta, round counter, PRNG key,
    # and (delay > 0) every slot of the history ring
    np.testing.assert_array_equal(np.asarray(restored.theta["w"]),
                                  np.asarray(mid.theta["w"]))
    np.testing.assert_array_equal(np.asarray(restored.key),
                                  np.asarray(mid.key))
    assert int(restored.t) == int(mid.t) == 12
    if delay:
        np.testing.assert_array_equal(np.asarray(restored.history["w"]),
                                      np.asarray(mid.history["w"]))
    resumed = rounds(restored, 12, 24)
    np.testing.assert_array_equal(np.asarray(resumed.theta["w"]),
                                  np.asarray(full.theta["w"]))


@pytest.mark.parametrize("delay", [0, 2])
@pytest.mark.parametrize("engine", ["sim", "dist"])
def test_run_resume_bit_identical(tmp_path, delay, engine):
    """run(checkpoint_every=)/run(resume=True) continues bit-identically
    for both engines, with and without the history ring."""
    spec = _delay_spec(delay)
    full = run(spec, engine=engine, chunk_rounds=8, warmup=False,
               compute_regret=False)
    d = str(tmp_path / "ckpt")
    run(spec, engine=engine, chunk_rounds=8, warmup=False,
        compute_regret=False, horizon=12, checkpoint_every=12,
        checkpoint_dir=d)
    res = run(spec, engine=engine, chunk_rounds=8, warmup=False,
              compute_regret=False, checkpoint_dir=d, resume=True)
    assert res.start_round == 12
    np.testing.assert_array_equal(res.final_w, full.final_w)
    np.testing.assert_array_equal(res.correct, np.asarray(full.correct)[12:])
