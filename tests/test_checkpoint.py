import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, tree)
    assert latest_step(d) == 12
    back = restore_checkpoint(d, tree, step=7)
    assert back["params"]["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(back["params"]["w"], np.float32),
                               np.arange(12).reshape(3, 4))
    np.testing.assert_array_equal(np.asarray(back["params"]["b"]), np.ones(4))
    assert int(back["step"]) == 7


def test_checkpoint_latest_of_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
