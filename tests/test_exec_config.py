"""ExecConfig: the consolidated execution-options object + the legacy shim.

run()/run_batch() accept `exec=ExecConfig(...)`; the old loose kwargs keep
working through a deprecation shim that warns ONCE per process and maps
them onto the same fields — so results are bit-identical across the two
spellings, typos fail loudly, and caller-specific fields are rejected by
the caller that cannot honor them.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.api import ExecConfig, RunSpec, run
from repro.api import exec_config as ec
from repro.api.runner import run_batch


def _spec(**kw):
    base = dict(nodes=4, dim=16, horizon=6, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 3})
    base.update(kw)
    return RunSpec(**base)


def test_exec_config_is_frozen_with_replace():
    cfg = ExecConfig(chunk_rounds=7)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.chunk_rounds = 8
    assert cfg.replace(warmup=False).warmup is False
    assert cfg.replace(warmup=False).chunk_rounds == 7
    assert cfg.chunk_rounds == 7            # original untouched


def test_legacy_kwargs_round_trip_bit_identical():
    """The shim maps loose kwargs onto the same execution — results match
    the exec= spelling to the bit."""
    spec = _spec()
    via_exec = run(spec, exec=ExecConfig(chunk_rounds=3, warmup=False,
                                         compute_regret=False))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        via_legacy = run(spec, chunk_rounds=3, warmup=False,
                         compute_regret=False)
    np.testing.assert_array_equal(via_exec.final_w, via_legacy.final_w)
    np.testing.assert_array_equal(via_exec.loss, via_legacy.loss)


def test_legacy_kwargs_warn_once():
    ec._warned_legacy = False
    spec = _spec()
    with pytest.warns(DeprecationWarning, match="ExecConfig"):
        run(spec, chunk_rounds=3, warmup=False, compute_regret=False)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        run(spec, chunk_rounds=3, warmup=False, compute_regret=False)


def test_unknown_kwarg_names_fields():
    with pytest.raises(TypeError, match="chunk_rounds"):
        run(_spec(), chunk=3)


def test_exec_and_legacy_together_raise():
    with pytest.raises(TypeError, match="both exec="):
        run(_spec(), exec=ExecConfig(), chunk_rounds=3)


def test_exec_must_be_exec_config():
    with pytest.raises(TypeError, match="ExecConfig"):
        run(_spec(), exec={"chunk_rounds": 3})


def test_run_rejects_batch_only_fields():
    with pytest.raises(ValueError, match="run_batch"):
        run(_spec(), exec=ExecConfig(devices=2, warmup=False))


def test_run_batch_rejects_run_only_fields():
    with pytest.raises(ValueError, match="run\\(\\)"):
        run_batch(_spec(), [0, 1],
                  exec=ExecConfig(print_every=5, warmup=False))


def test_run_batch_legacy_shim():
    spec = _spec()
    via_exec = run_batch(spec, [0, 1],
                         exec=ExecConfig(chunk_rounds=3, warmup=False,
                                         compute_regret=False))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        via_legacy = run_batch(spec, [0, 1], chunk_rounds=3, warmup=False,
                               compute_regret=False)
    for a, b in zip(via_exec, via_legacy):
        np.testing.assert_array_equal(a.final_w, b.final_w)


def test_defaults_match_old_signature_defaults():
    cfg = ExecConfig()
    assert cfg.chunk_rounds == 512
    assert cfg.compute_regret is True
    assert cfg.warmup is True
    assert cfg.resume is False
    assert cfg.checkpoint_every is None and cfg.checkpoint_dir is None
