"""HLO roll-up cost model validation (the §Roofline source)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost
from repro.launch.hlo_analysis import collective_stats, roofline_terms


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matmul_flops_exact():
    s = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(lambda a, b: a @ b, s, s)
    r = hlo_cost.analyze(c.as_text())
    assert r.flops == 2 * 512**3
    assert r.hbm_bytes == 3 * 512 * 512 * 4


def test_scan_trip_count_multiplied():
    def f(xs):
        def body(c, x):
            return c @ x, None
        c, _ = jax.lax.scan(body, jnp.eye(64), xs)
        return c
    s = jax.ShapeDtypeStruct((17, 64, 64), jnp.float32)
    r = hlo_cost.analyze(_compile(f, s).as_text())
    expect = 17 * 2 * 64**3
    assert abs(r.flops - expect) / expect < 0.05
    assert 17 in r.while_trip_counts
    # XLA's own count misses the loop: ours must be much larger
    xla_flops = hlo_cost.cost_analysis_get(_compile(f, s).cost_analysis(), "flops")
    assert r.flops > 5 * xla_flops


def test_elementwise_fusion_free_bytes():
    s = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda a, b: jnp.tanh(a @ b) * 2 + 1, s, s)
    r = hlo_cost.analyze(c.as_text())
    # fused estimate == matmul traffic only; unfused estimate is larger
    assert r.hbm_bytes <= 3 * 1024 * 1024 * 4 * 1.1
    assert r.hbm_bytes_unfused > r.hbm_bytes


def test_nested_scan():
    def f(xs):
        def outer(c, x):
            def inner(ci, xi):
                return ci + xi @ xi, None
            ci, _ = jax.lax.scan(inner, c, x)
            return ci, None
        c, _ = jax.lax.scan(outer, jnp.zeros((32, 32)), xs)
        return c
    s = jax.ShapeDtypeStruct((5, 7, 32, 32), jnp.float32)
    r = hlo_cost.analyze(_compile(f, s).as_text())
    expect = 5 * 7 * 2 * 32**3
    assert abs(r.flops - expect) / expect < 0.2


def test_collective_stats_on_sharded_program():
    import os
    # this test only inspects text parsing: fabricate a tiny HLO module
    hlo = """
HloModule test

ENTRY %main (p: f32[8,128]) -> f32[8,128] {
  %p = f32[8,128]{1,0} parameter(0)
  %cp = f32[8,128]{1,0} collective-permute(%p), channel_id=1, source_target_pairs={{0,1},{1,0}}
  %ar = f32[8,128]{1,0} all-reduce(%cp), channel_id=2, to_apply=%add
  ROOT %out = f32[8,128]{1,0} add(%ar, %p)
}
"""
    st = collective_stats(hlo)
    assert st.count_by_kind == {"collective-permute": 1, "all-reduce": 1}
    assert st.bytes_by_kind["collective-permute"] == 8 * 128 * 4
    assert st.bytes_by_kind["all-reduce"] == 8 * 128 * 4


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12, hbm_bytes=0.0, collective_bytes=0.0)
    assert t["dominant"] == "compute" and t["t_compute_s"] == 1.0
    t = roofline_terms(flops=0.0, hbm_bytes=819e9, collective_bytes=1.0)
    assert t["dominant"] == "memory" and t["t_memory_s"] == 1.0
    t = roofline_terms(flops=0.0, hbm_bytes=0.0, collective_bytes=50e9)
    assert t["dominant"] == "collective" and t["t_collective_s"] == 1.0
