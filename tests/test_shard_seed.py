"""Device-sharded seed axis — shard_map over a ("seed",) mesh.

The multi-device equivalence tests run in subprocesses with 8 fake CPU
devices (XLA_FLAGS=--xla_force_host_platform_device_count=8; the main
pytest process keeps the real 1-device view). The acceptance contract:

  * sharded per-seed trajectories are BIT-identical to the single-device
    vmap and to sequential `run()` — Laplace noise on, delay in {0, 2},
    both engines;
  * pad-and-mask seed counts work: S=5 on 4 devices matches sequential
    `run()` per seed, pad seeds never leak into any trajectory/aggregate;
  * checkpoints cross device counts: save on 4 devices, resume on 1
    (and the reverse) bit-identically.

The in-process tests cover the 1-device behavior: graceful fallback to the
vmap path, the error surfaces, and the SweepSpec/CLI threading.
"""
import os
import subprocess
import sys

import pytest

from repro.api import RunSpec, run, run_batch
from repro.launch.mesh import seed_mesh
from repro.sweep import SweepSpec, sweep

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = r"""
import numpy as np
from repro.api import RunSpec, run, run_batch

FIELDS = ("final_w", "loss", "correct", "w_bar_loss", "sparsity",
          "eps_ledger")


def spec(**kw):
    base = dict(nodes=3, dim=16, horizon=14, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7})
    base.update(kw)
    return RunSpec(**base)


def assert_identical(a, b, what):
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{what}: field {f} diverged")
    assert a.accuracy == b.accuracy, what
"""


def _run(code: str, timeout=520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", _PRELUDE + code],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# -- multi-device equivalence (subprocesses, 8 fake devices) -----------------

@pytest.mark.slow
def test_sharded_bit_identical_to_vmap_and_sequential():
    """devices=8: per-seed trajectories match the single-device vmap AND
    sequential run(), noise on, delay in {0, 2}, both engines (S=6 pads
    to 8)."""
    out = _run(r"""
import jax
assert jax.local_device_count() == 8
seeds = list(range(6))
for engine in ("sim", "dist"):
    for delay in (0, 2):
        sp = spec(delay=delay)
        sharded = run_batch(sp, seeds, engine=engine, chunk_rounds=7,
                            warmup=False, compute_regret=False, devices=8)
        assert sharded[0].metrics["batch"]["devices"] == 8
        assert sharded[0].metrics["batch"]["pad_seeds"] == 2
        vmapped = run_batch(sp, seeds, engine=engine, chunk_rounds=7,
                            warmup=False, compute_regret=False)
        for s, sh, vm in zip(seeds, sharded, vmapped):
            assert_identical(sh, vm, f"{engine}/delay={delay}/seed={s} "
                                     "sharded vs vmap")
            seq = run(sp.replace(seed=s), engine=engine, chunk_rounds=7,
                      warmup=False, compute_regret=False)
            assert_identical(sh, seq, f"{engine}/delay={delay}/seed={s} "
                                      "sharded vs sequential")
        print(engine, delay, "OK")
""")
    assert out.count("OK") == 4


@pytest.mark.slow
def test_pad_and_mask_non_divisible_seed_count():
    """S=5 on 4 devices (pad to 8/
    mask back to 5) matches sequential run() per seed on both engines."""
    out = _run(r"""
seeds = list(range(5))
for engine in ("sim", "dist"):
    sharded = run_batch(spec(delay=1), seeds, engine=engine, chunk_rounds=7,
                        warmup=False, compute_regret=False, devices=4)
    info = sharded[0].metrics["batch"]
    assert info["devices"] == 4 and info["pad_seeds"] == 3, info
    assert len(sharded) == 5                      # pad seeds masked out
    assert {tuple(r.metrics["batch"]["seeds"]) for r in sharded} \
        == {tuple(seeds)}
    for s, sh in zip(seeds, sharded):
        seq = run(spec(delay=1).replace(seed=s), engine=engine,
                  chunk_rounds=7, warmup=False, compute_regret=False)
        assert_identical(sh, seq, f"{engine}/seed={s}")
    print(engine, "OK")
""")
    assert out.count("OK") == 2


@pytest.mark.slow
def test_checkpoint_crosses_device_counts():
    """A batch checkpoint saved under 4 devices resumes bit-identically
    under 1 (vmap), and a 1-device checkpoint resumes under 4 — the saved
    state is the gathered, pad-stripped host array."""
    out = _run(r"""
import tempfile
sp = spec(delay=1, horizon=24)
seeds = (0, 1, 2, 3, 4)
full = run_batch(sp, seeds, chunk_rounds=6, warmup=False,
                 compute_regret=False)
# save on 4 devices -> resume on 1
ck = tempfile.mkdtemp()
run_batch(sp, seeds, chunk_rounds=6, warmup=False, compute_regret=False,
          checkpoint_every=12, checkpoint_dir=ck, horizon=12, devices=4)
resumed = run_batch(sp, seeds, chunk_rounds=6, warmup=False,
                    checkpoint_dir=ck, resume=True, compute_regret=False)
assert resumed[0].start_round == 12
for f, r in zip(full, resumed):
    np.testing.assert_array_equal(f.final_w, r.final_w)
    np.testing.assert_array_equal(np.asarray(f.correct)[12:],
                                  np.asarray(r.correct))
seq = run(sp.replace(seed=seeds[1]), chunk_rounds=24, warmup=False,
          compute_regret=False)
np.testing.assert_array_equal(seq.final_w, resumed[1].final_w)
# save on 1 device -> resume on 4
ck2 = tempfile.mkdtemp()
run_batch(sp, seeds, chunk_rounds=6, warmup=False, compute_regret=False,
          checkpoint_every=12, checkpoint_dir=ck2, horizon=12)
resumed2 = run_batch(sp, seeds, chunk_rounds=6, warmup=False,
                     checkpoint_dir=ck2, resume=True, compute_regret=False,
                     devices=4)
for f, r in zip(full, resumed2):
    np.testing.assert_array_equal(f.final_w, r.final_w)
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_sweep_engine_sharded_matches_vmap():
    """SweepSpec(devices=) threads through sweep() and agrees with the
    unsharded sweep per (point, seed); seed_vectorizable still gates the
    sharded path into the sequential fallback."""
    out = _run(r"""
import numpy as np
from repro.sweep import SweepSpec, sweep
base = spec()
sharded = sweep(SweepSpec(base=base, axes={"eps": (0.5, 1.0)},
                          seeds=(0, 1, 2), chunk_rounds=7,
                          compute_regret=False, devices=4),
                store=None, warmup=False)
plain = sweep(SweepSpec(base=base, axes={"eps": (0.5, 1.0)},
                        seeds=(0, 1, 2), chunk_rounds=7,
                        compute_regret=False),
              store=None, warmup=False)
for prs, vrs in zip(sharded.results, plain.results):
    for a, b in zip(prs, vrs):
        assert_identical(a, b, "sweep sharded vs vmap")
# a seed-dependent stage must still fall back sequentially, devices or not
dd = spec(delay=2, delay_dist="uniform", horizon=7)
fb = sweep(SweepSpec(base=dd, seeds=(0, 1), chunk_rounds=7,
                     compute_regret=False, devices=4),
           store=None, warmup=False)
for s, res in zip((0, 1), fb.results[0]):
    seq = run(dd.replace(seed=s), chunk_rounds=7, warmup=False,
              compute_regret=False)
    assert_identical(res, seq, f"fallback seed={s}")
print("OK")
""")
    assert "OK" in out


# -- 1-device behavior (in-process) ------------------------------------------

def _spec(**kw):
    base = dict(nodes=3, dim=16, horizon=12, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7})
    base.update(kw)
    return RunSpec(**base)


def test_seed_mesh_single_device_fallback():
    """On a 1-device host, 'auto'/1/None all mean: stay on the vmap path."""
    import jax
    if jax.local_device_count() != 1:
        pytest.skip("needs the default 1-device test process")
    assert seed_mesh(None) is None
    assert seed_mesh(0) is None
    assert seed_mesh(1) is None
    assert seed_mesh("auto") is None


def test_seed_mesh_too_many_devices_errors():
    import jax
    want = jax.local_device_count() + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        seed_mesh(want)


def test_run_batch_devices_auto_graceful_on_one_device():
    """devices='auto' on a 1-device host is exactly the vmap path."""
    import jax
    import numpy as np
    if jax.local_device_count() != 1:
        pytest.skip("exercises the 1-device fallback specifically")
    sp = _spec()
    auto = run_batch(sp, (0, 1), chunk_rounds=6, warmup=False,
                     compute_regret=False, devices="auto")
    plain = run_batch(sp, (0, 1), chunk_rounds=6, warmup=False,
                      compute_regret=False)
    for a, b in zip(auto, plain):
        np.testing.assert_array_equal(a.final_w, b.final_w)
        np.testing.assert_array_equal(np.asarray(a.loss),
                                      np.asarray(b.loss))
    assert auto[0].metrics["batch"]["devices"] == 1
    assert auto[0].metrics["batch"]["pad_seeds"] == 0


def test_run_batch_rejects_mesh_without_seed_axis():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="'seed' axis"):
        run_batch(_spec(), (0, 1), mesh=mesh, chunk_rounds=6, warmup=False)


def test_sweepspec_devices_validation():
    SweepSpec(base=_spec(), devices=None)
    SweepSpec(base=_spec(), devices="auto")
    SweepSpec(base=_spec(), devices=4)
    with pytest.raises(ValueError, match="devices"):
        SweepSpec(base=_spec(), devices=0)
    with pytest.raises(ValueError, match="devices"):
        SweepSpec(base=_spec(), devices="many")


def test_sweep_devices_auto_on_one_device():
    """sweep(devices='auto') on a 1-device host falls back to vmap and still
    matches sequential run() per seed."""
    import numpy as np
    sw = SweepSpec(base=_spec(), seeds=(0, 1), chunk_rounds=6,
                   compute_regret=False, devices="auto")
    out = sweep(sw, store=None, warmup=False)
    for s, res in zip((0, 1), out.results[0]):
        seq = run(_spec().replace(seed=s), chunk_rounds=6, warmup=False,
                  compute_regret=False)
        np.testing.assert_array_equal(res.final_w, seq.final_w)


def test_cli_devices_parsing(tmp_path):
    from repro.launch.sweep import main
    out = main(["--nodes", "3", "--dim", "16", "--horizon", "6",
                "--axis", "eps=0.5", "--seeds", "0,1",
                "--chunk-rounds", "6", "--no-regret", "--devices", "auto",
                "--store", str(tmp_path), "--name", "t_dev"])
    assert out["summary"]["ran_points"] == 1
