import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.privacy import (
    PrivacyAccountant, PrivacyConfig, clip_by_l2, laplace_scale, sample_laplace,
    sample_laplace_tree, sensitivity,
)


def test_sensitivity_lemma1():
    # S(t) <= 2 alpha sqrt(n) L
    assert float(sensitivity(0.1, 100, 1.0)) == pytest.approx(2 * 0.1 * 10 * 1.0)


def test_laplace_scale_eq8():
    assert float(laplace_scale(0.1, 100, 1.0, 0.5)) == pytest.approx(2 * 0.1 * 10 / 0.5)
    assert float(laplace_scale(0.1, 100, 1.0, math.inf)) == 0.0


def test_laplace_empirical_scale():
    key = jax.random.PRNGKey(0)
    b = 2.5
    x = sample_laplace(key, (200_000,), b)
    # Laplace(b): E|x| = b, Var = 2 b^2
    assert float(jnp.mean(jnp.abs(x))) == pytest.approx(b, rel=0.02)
    assert float(jnp.var(x)) == pytest.approx(2 * b * b, rel=0.05)


def test_laplace_zero_scale_is_zero():
    x = sample_laplace(jax.random.PRNGKey(1), (100,), 0.0)
    assert float(jnp.max(jnp.abs(x))) == 0.0


def test_laplace_tree_independent_leaves():
    tree = {"a": jnp.zeros((20_000,)), "b": jnp.zeros((20_000,))}
    noise = sample_laplace_tree(jax.random.PRNGKey(2), tree, 1.0)
    corr = np.corrcoef(np.asarray(noise["a"]), np.asarray(noise["b"]))[0, 1]
    assert abs(corr) < 0.05


@pytest.mark.parametrize("norm_target", [
    0.01, 0.1, 0.5, 1.0, 2.0, 5.0, 8.0, 9.0, 9.5, 10.0,
])
def test_clip_by_l2(norm_target):
    tree = {"w": jnp.full((64,), 2.0), "b": jnp.full((8,), -1.0)}
    clipped, pre = clip_by_l2(tree, norm_target)
    post = math.sqrt(sum(float(jnp.sum(jnp.square(l))) for l in jax.tree.leaves(clipped)))
    assert post <= norm_target * (1 + 1e-5)
    if float(pre) <= norm_target:  # no-op when already inside the ball
        np.testing.assert_allclose(np.asarray(clipped["w"]), 2.0, rtol=1e-6)


def test_privacy_config_coordinate_style():
    cfg = PrivacyConfig(eps=1.0, L=1.0, clip_style="coordinate")
    # per-coordinate scale has no sqrt(n) factor
    assert float(cfg.scale_for(0.1, 10_000)) == pytest.approx(0.2)
    g = PrivacyConfig(eps=1.0, L=1.0, clip_style="global")
    assert float(g.scale_for(0.1, 10_000)) == pytest.approx(0.2 * 100)


def test_accountant_parallel_composition():
    acc = PrivacyAccountant(eps_per_round=0.5)
    for _ in range(100):
        acc.step()
    assert acc.guarantee == 0.5  # Thm 1: disjoint rounds don't compound
    seq = PrivacyAccountant(eps_per_round=0.5, disjoint_streams=False)
    seq.step(100)
    assert seq.guarantee == pytest.approx(50.0)


def test_accountant_zero_rounds_guarantees_zero():
    """Fix: before the first broadcast NOTHING has been released, so the
    guarantee is 0 — the old code claimed eps_per_round at rounds == 0."""
    assert PrivacyAccountant(eps_per_round=0.5).guarantee == 0.0
    assert PrivacyAccountant(eps_per_round=0.5,
                             disjoint_streams=False).guarantee == 0.0


def test_accountant_guarantee_at_trajectory():
    par = PrivacyAccountant(eps_per_round=0.25)
    assert [par.guarantee_at(t) for t in (0, 1, 7, 10_000)] == \
        [0.0, 0.25, 0.25, 0.25]
    seq = PrivacyAccountant(eps_per_round=0.25, disjoint_streams=False)
    assert [seq.guarantee_at(t) for t in (0, 1, 4)] == [0.0, 0.25, 1.0]


def test_accountant_ledger():
    par = PrivacyAccountant(eps_per_round=2.0)
    par.step(3)
    assert par.ledger() == [2.0, 2.0, 2.0]
    seq = PrivacyAccountant(eps_per_round=2.0, disjoint_streams=False)
    seq.step(2)
    assert seq.ledger() == [2.0, 4.0]
    assert seq.ledger(rounds=4) == [2.0, 4.0, 6.0, 8.0]


def test_accountant_rejects_invalid_input():
    with pytest.raises(ValueError):
        PrivacyAccountant(eps_per_round=-1.0)
    with pytest.raises(ValueError):
        PrivacyAccountant(eps_per_round=1.0, rounds=-3)
    acc = PrivacyAccountant(eps_per_round=1.0)
    with pytest.raises(ValueError):
        acc.step(-1)
    assert acc.rounds == 0  # the failed step must not half-apply
