"""MoE dispatch correctness: sort-based capacity dispatch vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import ModelConfig


def _cfg(E=4, k=2, cf=8.0, shared=False):
    return ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                       num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                       num_experts=E, num_experts_per_tok=k,
                       moe_capacity_factor=cf, shared_expert=shared,
                       dtype="float32")


def _dense_reference(p, cfg, x):
    """Dense einsum over ALL experts weighted by the (sparse) combine weights."""
    B, T, D = x.shape
    xf = x.reshape(-1, D)
    weights, top_idx, _ = moe._router(p, cfg, xf)
    E = cfg.num_experts
    comb = jnp.zeros((xf.shape[0], E))
    for j in range(cfg.num_experts_per_tok):
        comb = comb.at[jnp.arange(xf.shape[0]), top_idx[:, j]].add(weights[:, j])
    h = jax.nn.silu(jnp.einsum("nd,edf->nef", xf, p["gate"])) * jnp.einsum(
        "nd,edf->nef", xf, p["up"])
    y_all = jnp.einsum("nef,efd->ned", h, p["down"])
    y = jnp.einsum("ned,ne->nd", y_all, comb)
    if cfg.shared_expert:
        from repro.models import mlp as mlp_mod
        y = y + mlp_mod.mlp(p["shared"], cfg, xf)
    return y.reshape(B, T, D)


@pytest.mark.parametrize("E,k,shared", [(4, 2, False), (4, 1, False), (4, 1, True)])
def test_moe_matches_dense_reference(E, k, shared):
    cfg = _cfg(E=E, k=k, shared=shared)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe.moe_apply(p, cfg, x)
    y_ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-5)
    assert float(aux) >= 0


def test_moe_capacity_drops_tokens():
    """With capacity factor << 1 some tokens must be dropped (output zeros)."""
    cfg = _cfg(cf=0.25)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    y, _ = moe.moe_apply(p, cfg, x)
    y_ref = _dense_reference(p, cfg, x)
    # dropped tokens make y != y_ref somewhere, but never NaN
    assert not bool(jnp.any(jnp.isnan(y)))
    assert not np.allclose(np.asarray(y), np.asarray(y_ref))


def test_router_aux_loss_penalizes_imbalance():
    cfg = _cfg()
    n, E = 512, cfg.num_experts
    # balanced assignments
    logits_bal = jnp.tile(jnp.eye(E), (n // E, 1)) * 10
    # collapsed assignments (everyone to expert 0)
    logits_col = jnp.zeros((n, E)).at[:, 0].set(10.0)
    p_bal = {"router": {"w": jnp.eye(32, E)}}

    def aux_of(logits):
        probs = jax.nn.softmax(logits, axis=-1)
        top = jnp.argmax(logits, axis=-1)
        f_e = jax.nn.one_hot(top, E).mean(0)
        P_e = probs.mean(0)
        return float(E * jnp.sum(f_e * P_e))

    assert aux_of(logits_col) > aux_of(logits_bal)


def test_moe_grads_flow_to_experts_and_router():
    cfg = _cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))

    def loss(p):
        y, aux = moe.moe_apply(p, cfg, x)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["gate"]))) > 0
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0
