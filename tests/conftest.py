"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the real single
CPU device; distributed tests spawn subprocesses with their own flags."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
