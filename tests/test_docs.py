"""Docs health: the `>>>` examples in docs/*.md and the repro.api module
docstrings must run green, and README links must resolve. CI runs this file
in a dedicated docs job (.github/workflows/ci.yml)."""
import doctest
import importlib
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ("docs/algorithm.md", "docs/privacy.md", "docs/delayed_gossip.md",
        "docs/streams.md", "docs/sweeps.md", "docs/serving.md",
        "docs/node_sharding.md", "docs/faults.md", "docs/observability.md",
        "docs/kernels.md")
API_MODULES = (
    "repro.api",
    "repro.api.registry",
    "repro.api.spec",
    "repro.api.mixers",
    "repro.api.mechanisms",
    "repro.api.rules",
    "repro.api.clippers",
    "repro.api.streams",
    "repro.api.runner",
    "repro.api.shard_node",
    "repro.api.exec_config",
    "repro.api.backends",
    "repro.sweep",
    "repro.sweep.spec",
    "repro.sweep.store",
    "repro.sweep.engine",
    "repro.sweep.plot",
    "repro.faults",
    "repro.faults.spec",
    "repro.faults.schedule",
    "repro.faults.mixers",
    "repro.faults.metrics",
    "repro.serve",
    "repro.serve.state",
    "repro.serve.admission",
    "repro.serve.trainer",
    "repro.serve.replay",
    "repro.serve.service",
    "repro.checkpoint.async_writer",
    "repro.obs",
    "repro.obs.trace",
    "repro.obs.metrics",
    "repro.obs.events",
    "repro.obs.cost",
    "repro.launch.obs",
    "repro.metrics.logging",
)
FLAGS = doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE


@pytest.mark.parametrize("page", DOCS)
def test_docs_page_doctests(page):
    path = os.path.join(ROOT, page)
    result = doctest.testfile(path, module_relative=False, optionflags=FLAGS,
                              verbose=False)
    assert result.attempted > 0, f"{page} has no runnable >>> examples"
    assert result.failed == 0, f"{page}: {result.failed} doctest failures"


@pytest.mark.parametrize("mod", API_MODULES)
def test_api_module_doctests(mod):
    result = doctest.testmod(importlib.import_module(mod), optionflags=FLAGS,
                             verbose=False)
    assert result.attempted > 0, f"{mod} docstrings have no >>> examples"
    assert result.failed == 0, f"{mod}: {result.failed} doctest failures"


_LINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)]*)?\)")


def _relative_links(md_path):
    text = open(md_path).read()
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        yield target


@pytest.mark.parametrize("page", ("README.md",) + DOCS)
def test_markdown_links_resolve(page):
    path = os.path.join(ROOT, page)
    base = os.path.dirname(path)
    missing = [t for t in _relative_links(path)
               if not os.path.exists(os.path.join(base, t))]
    assert not missing, f"{page}: broken relative links {missing}"


def test_readme_links_the_docs_pages():
    text = open(os.path.join(ROOT, "README.md")).read()
    for page in DOCS:
        assert page in text, f"README does not link {page}"
