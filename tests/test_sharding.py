import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.sharding import rules


class _FakeMesh:
    """shape-only mesh stand-in for the divisibility sanitizer."""
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_param_rules_basic_paths():
    params = jax.eval_shape(
        lambda: build_model(get_config("qwen2-7b").reduced()).init(jax.random.PRNGKey(0)))
    specs = rules.param_pspecs(params)
    # embed vocab-sharded; attention/ffn 2D-sharded; norms replicated
    assert specs["embed"]["table"] == P("model", None)
    assert specs["final_norm"]["scale"] == P()
    layer = specs["layers"]
    assert layer["attn"]["wq"]["w"][-1] == "model"
    assert layer["attn"]["wo"]["w"][-2] == "model"
    assert layer["ffn"]["gate"]["w"][-1] == "model"
    assert layer["ffn"]["down"]["w"][-2] == "model"


def test_sanitizer_moves_indivisible_vocab():
    params = jax.eval_shape(
        lambda: build_model(get_config("minicpm-2b")).init(jax.random.PRNGKey(0)))
    mesh = _FakeMesh(data=16, model=16)
    specs = rules.param_pspecs(params, mesh=mesh)
    # padded vocab (122880) divides 16 -> vocab stays sharded
    # (sanitizer pops trailing Nones: P('model') == P('model', None))
    assert specs["embed"]["table"][0] == "model"
    assert params["embed"]["table"].shape[0] % 16 == 0


def test_sanitizer_drops_or_moves():
    class _L:
        shape = (10, 64)
        ndim = 2
    spec = rules._sanitize(P("model", None), (10, 64), _FakeMesh(data=4, model=16))
    # 10 % 16 != 0 -> moved to dim 1 (64 % 16 == 0)
    assert spec == P(None, "model")
    spec2 = rules._sanitize(P("model",), (10,), _FakeMesh(model=16))
    assert spec2 == P()


def test_node_axis_prepended():
    params = {"ffn": {"gate": {"w": jax.ShapeDtypeStruct((4, 64, 128), jnp.float32)}}}
    specs = rules.param_pspecs(params, node_axes=("data",))
    assert specs["ffn"]["gate"]["w"][0] == "data"
    assert specs["ffn"]["gate"]["w"][2] == "model"


def test_cache_specs():
    cfg = get_config("qwen2-7b").reduced()
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(8, 64))
    specs = rules.cache_pspecs(cache, ("data",))
    k_spec = specs["attn"]["k"]  # stacked (L, B, C, kv, hd)
    assert k_spec[-4] == "data" and k_spec[-2] == "model"
    sp = specs["attn"]["slot_pos"]
    assert sp[-2] == "data"
