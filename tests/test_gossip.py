"""GossipDP distributed-strategy unit tests (single device; sharded-lowering
equivalence is in test_distributed.py)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import MIXERS, RunSpec
from repro.core import GossipDP, OMDConfig
from repro.core.gossip import gossip_mix_tree, per_node_clip
from repro.core.graph import complete_matrix, ring_matrix


def _theta(m=8, n=32, key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (m, n)), "b": jax.random.normal(k, (m, 4))}


def _gdp(topology="ring", m=8, eps=1.0, alpha0=0.5, lam=0.05, **spec_kw):
    return RunSpec(nodes=m, mixer=topology, mechanism="laplace",
                   eps=eps, clip_norm=1.0, calibration="global",
                   alpha0=alpha0, schedule="sqrt_t", lam=lam,
                   **spec_kw).build_distributed()


@pytest.mark.parametrize("topology,matrix_fn", [
    ("ring", lambda m: ring_matrix(m, 0.5)),
    ("complete", complete_matrix),
])
def test_mix_equals_dense_matrix(topology, matrix_fn):
    m = 8
    theta = _theta(m)
    mixer = MIXERS.build(topology, m=m, self_weight=0.5)
    mixed = gossip_mix_tree(theta, jax.random.PRNGKey(1), jnp.zeros(()), mixer,
                            True, jnp.zeros((), jnp.int32))
    A = matrix_fn(m)
    for leafname in ("w", "b"):
        np.testing.assert_allclose(
            np.asarray(mixed[leafname]), A @ np.asarray(theta[leafname]),
            rtol=1e-5, atol=1e-6)


def test_disconnected_is_identity():
    theta = _theta()
    mixer = MIXERS.build("disconnected", m=8)
    mixed = gossip_mix_tree(theta, jax.random.PRNGKey(1), jnp.asarray(5.0),
                            mixer, True, jnp.zeros((), jnp.int32))
    np.testing.assert_array_equal(np.asarray(mixed["w"]), np.asarray(theta["w"]))


def test_mix_preserves_mean_noise_free():
    theta = _theta()
    for topo in ("ring", "complete", "ring_alternating"):
        mixer = MIXERS.build(topo, m=8)
        mixed = gossip_mix_tree(theta, jax.random.PRNGKey(1), jnp.zeros(()),
                                mixer, True, jnp.zeros((), jnp.int32))
        np.testing.assert_allclose(
            np.asarray(mixed["w"].mean(0)), np.asarray(theta["w"].mean(0)),
            rtol=1e-4, atol=1e-5)


def test_ring_alternating_switches_direction():
    theta = _theta()
    mixer = MIXERS.build("ring_alternating", m=8)
    even = gossip_mix_tree(theta, jax.random.PRNGKey(1), jnp.zeros(()), mixer,
                           True, jnp.zeros((), jnp.int32))
    odd = gossip_mix_tree(theta, jax.random.PRNGKey(1), jnp.zeros(()), mixer,
                          True, jnp.ones((), jnp.int32))
    assert not np.allclose(np.asarray(even["w"]), np.asarray(odd["w"]))


def test_noise_self_false_removes_own_noise():
    """Noise-free equivalence of the noise_self variants (complete graph)."""
    m, n = 4, 16
    theta = {"w": jnp.ones((m, n))}
    mixer = MIXERS.build("complete", m=m)
    a = gossip_mix_tree(theta, jax.random.PRNGKey(0), jnp.zeros(()), mixer,
                        True, jnp.zeros((), jnp.int32))
    b = gossip_mix_tree(theta, jax.random.PRNGKey(0), jnp.zeros(()), mixer,
                        False, jnp.zeros((), jnp.int32))
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]), rtol=1e-6)


@pytest.mark.parametrize("L", [0.1, 0.37, 1.0, 2.5, 5.0, 9.99, 10.0, 20.0])
def test_per_node_clip(L):
    grads = {"w": jnp.full((4, 100), 1.0)}  # per-node norm = 10
    clipped, norms = per_node_clip(grads, L)
    np.testing.assert_allclose(np.asarray(norms), 10.0, rtol=1e-5)
    got = float(jnp.linalg.norm(clipped["w"][0]))
    assert got <= min(L, 10.0) * (1 + 1e-5)


def test_gossip_dp_update_end_to_end():
    m, n = 8, 64
    gdp = _gdp(eps=1.0, m=m)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, n))}
    state = gdp.init(params, jax.random.PRNGKey(1))
    grads = {"w": jnp.ones((m, n))}
    state2, metrics = gdp.update(state, grads)
    assert int(state2.t) == 1
    assert float(metrics["noise_scale"]) > 0
    assert np.isfinite(np.asarray(state2.theta["w"])).all()
    # primal applies the Lasso prox
    w = gdp.primal(state2)
    assert float(jnp.mean((w["w"] == 0).astype(jnp.float32))) >= 0.0
    # nonprivate path: noise scale exactly 0
    gdp_np = _gdp(eps=math.inf, m=m)
    st_np = gdp_np.init(params, jax.random.PRNGKey(1))
    _, m_np = gdp_np.update(st_np, grads)
    assert float(m_np["noise_scale"]) == 0.0


def test_gossip_matches_simulator_one_round():
    """Distributed-tree update == dense-A simulator update (noise-free)."""
    m, n = 8, 32
    key = jax.random.PRNGKey(3)
    theta0 = jax.random.normal(key, (m, n))
    grads = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    alpha = 1.0  # sqrt_t at t=1

    gdp = RunSpec(nodes=m, mixer="ring", mechanism="laplace", eps=math.inf,
                  clip_norm=1e9, calibration="global", alpha0=1.0,
                  schedule="sqrt_t", lam=0.0).build_distributed()
    state = gdp.init({"w": theta0}, key)
    state2, _ = gdp.update(state, {"w": grads})

    A = ring_matrix(m, 0.5)
    expected = A @ np.asarray(theta0) - alpha * np.asarray(grads)
    np.testing.assert_allclose(np.asarray(state2.theta["w"]), expected,
                               rtol=1e-4, atol=1e-5)


def test_legacy_constructor_kwargs_removed():
    """The one-release deprecation window is over: gossip=/privacy= are gone."""
    with pytest.raises(TypeError):
        GossipDP(omd=OMDConfig(), gossip=object(), privacy=object())
