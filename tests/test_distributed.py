"""Distributed lowering/equivalence tests — run in subprocesses with 8 fake
devices (the main pytest process keeps the real 1-device view)."""
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_gossip_ring_lowers_to_collective_permute():
    out = _run(r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.api import RunSpec
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("data",))
theta = {"w": jnp.ones((8, 256))}
gdp = RunSpec(nodes=8, mixer="ring", mechanism="laplace", eps=1.0,
              clip_norm=1.0, calibration="global", alpha0=0.1,
              lam=0.01).build_distributed()
state = gdp.init(jax.device_put(theta, NamedSharding(mesh, P("data", None))), jax.random.PRNGKey(0))
hlo = jax.jit(gdp.update).lower(state, theta).compile().as_text()
print("PERMUTE" if "collective-permute" in hlo else "NOPERMUTE")
# theta mixing must NOT require an all-gather of the full node dim
print("OK")
""")
    assert "PERMUTE" in out


@pytest.mark.slow
def test_delayed_gossip_lowers_sharded_with_history_ring():
    """The history ring shards like theta (ring axis unsharded) and the
    delayed exchange still lowers without an all-gather of the node dim."""
    out = _run(r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.api import RunSpec
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("data",))
theta = {"w": jnp.ones((8, 256))}
gdp = RunSpec(nodes=8, mixer="ring", mechanism="laplace", eps=1.0,
              clip_norm=1.0, calibration="global", alpha0=0.1,
              lam=0.01, delay=2).build_distributed()
state = gdp.init(jax.device_put(theta, NamedSharding(mesh, P("data", None))), jax.random.PRNGKey(0))
assert state.history["w"].shape == (3, 8, 256)
state2, _ = jax.jit(gdp.update)(state, theta)
assert state2.history["w"].shape == (3, 8, 256)
hlo = jax.jit(gdp.update).lower(state, theta).compile().as_text()
print("PERMUTE" if "collective-permute" in hlo else "NOPERMUTE")
print("OK")
""")
    assert "PERMUTE" in out


@pytest.mark.slow
def test_distributed_gossip_equals_simulator():
    """Sharded GossipDP rounds == dense-A Algorithm1 simulator (noise-free)."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np, math, json
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.api import RunSpec
from repro.core.algorithm1 import hinge_loss_and_grad

from repro.launch.mesh import make_mesh
m, n, T = 8, 64, 20
mesh = make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
xs = jax.random.normal(key, (T, m, n)) / np.sqrt(n)
ys = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (T, m)))

spec = RunSpec(nodes=m, dim=n, mixer="ring", mechanism="laplace",
               eps=math.inf, clip_norm=1.0, calibration="global",
               alpha0=0.5, schedule="sqrt_t", lam=0.01)

# simulator
alg = spec.build_simulator()
w_sim, outs = alg.final_params(jax.random.PRNGKey(9), xs, ys)

# distributed: same math via GossipDP on a sharded node axis
gdp = spec.build_distributed()
sharding = NamedSharding(mesh, P("data", None))
state = gdp.init({"w": jax.device_put(jnp.zeros((m, n)), sharding)}, jax.random.PRNGKey(9))

@jax.jit
def round_fn(state, batch):
    x, y = batch
    w = gdp.primal(state)["w"]
    loss, grad = hinge_loss_and_grad(w, x, y)
    # clip exactly like the simulator
    gnorm = jnp.linalg.norm(grad, axis=1, keepdims=True)
    grad = grad * jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-12))
    new_state, _ = gdp.update(state, {"w": grad})
    return new_state

for t in range(T):
    state = round_fn(state, (xs[t], ys[t]))

w_dist = gdp.primal(state)["w"]
err = float(jnp.max(jnp.abs(w_dist - w_sim)))
print(json.dumps({"max_err": err}))
""")
    err = json.loads(out.strip().splitlines()[-1])["max_err"]
    assert err < 1e-4, err


@pytest.mark.slow
def test_sharded_train_and_serve_lower_all_families():
    """One arch per family lowers+runs on a 4x2 test mesh."""
    out = _run(r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.launch import steps
from repro.launch.mesh import make_test_mesh
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.sharding import rules as shard_rules

mesh = make_test_mesh(4, 2)
shape = ShapeConfig("t", 64, 8, "train")
for arch in ("qwen3-32b", "mixtral-8x7b", "rwkv6-3b", "recurrentgemma-2b", "seamless-m4t-medium"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    with mesh:
        gdp = steps.make_gossip_dp(4, steps.TrainRecipe())
        step = steps.make_gossip_train_step(model, gdp)
        init = steps.make_gossip_init(model, gdp, 4)
        state_struct = jax.eval_shape(init)
        tsp = shard_rules.param_pspecs(state_struct.gossip.theta, node_axes=("data",), mesh=mesh)
        ssp = steps.gossip_state_pspecs(state_struct, tsp)
        bs, bsp = steps.train_batch_specs(cfg, shape, mesh, "gossip")
        fn = jax.jit(step, in_shardings=(steps.named(mesh, ssp), steps.named(mesh, bsp)),
                     donate_argnums=(0,))
        state = init(0)
        batch = {k: jnp.zeros(v.shape, v.dtype) for k, v in bs.items()}
        if "labels" in batch:
            batch["labels"] = jnp.ones_like(batch["labels"])
        _, metrics = fn(state, batch)
        assert float(metrics["loss"]) > 0
        print(arch, "OK")
""", timeout=560)
    assert out.count("OK") == 5


@pytest.mark.slow
def test_multipod_mesh_function():
    out = _run(r"""
import os
import jax
# 8 devices -> shrink the production mesh shape proportionally via test mesh
from repro.launch.mesh import gossip_axes, gossip_nodes, make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
assert gossip_axes(mesh) == ("pod",)
assert gossip_nodes(mesh) == 2
print("OK")
""")
    assert "OK" in out
