"""Flash-attention Pallas kernel + custom-VJP twin: allclose sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import attention


def _qkv(B, T, H, Kv, hd, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (B, T, H, hd), dtype)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, T, Kv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, T, Kv, hd), dtype)
    return q, kk, v


@pytest.mark.parametrize("T,H,Kv,hd", [(128, 4, 4, 32), (256, 4, 2, 64),
                                       (96, 8, 1, 32)])
def test_flash_kernel_matches_oracle(T, H, Kv, hd):
    q, k, v = _qkv(2, T, H, Kv, hd)
    o = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                        interpret=True)
    ref = attention._full_attention(q, k, v, jnp.arange(T), jnp.arange(T),
                                    None, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_kernel_window():
    q, k, v = _qkv(1, 128, 4, 2, 32)
    o = flash_attention(q, k, v, causal=True, window=48, block_q=32,
                        block_k=32, interpret=True)
    ref = attention._full_attention(q, k, v, jnp.arange(128), jnp.arange(128),
                                    48, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    q, k, v = _qkv(1, 128, 4, 4, 64, dtype=jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    ref = attention._full_attention(q, k, v, jnp.arange(128), jnp.arange(128),
                                    None, None)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_kernel_nondivisible_seq():
    q, k, v = _qkv(1, 100, 2, 2, 32)
    o = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                        interpret=True)
    ref = attention._full_attention(q, k, v, jnp.arange(100), jnp.arange(100),
                                    None, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 48])
def test_flash_vjp_matches_autodiff(window):
    """The custom-VJP twin (used in training): grads == naive autodiff."""
    q, k, v = _qkv(2, 128, 4, 2, 32, seed=3)
    do = jax.random.normal(jax.random.PRNGKey(9), q.shape)

    def naive(q, k, v):
        o = attention._blockwise_attention(q, k, v, window, None,
                                           q_chunk=32, k_chunk=32)
        return jnp.sum(o * do)

    def flash(q, k, v):
        o = attention._flash_attention_jax(q, k, v, window, None, 32, 32)
        return jnp.sum(o * do)

    g1 = jax.grad(naive, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_flash_vjp_under_model_training():
    """End-to-end: a train step with flash_vjp on == off (same loss/grads)."""
    from repro.configs import get_config
    from repro.models import build_model
    import dataclasses

    cfg = dataclasses.replace(get_config("qwen2-7b").reduced(), num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 1, 2 * attention.BLOCKWISE_THRESHOLD  # force the blockwise path
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}

    def loss(p):
        return model.loss_fn(p, batch)[0]

    l_off, g_off = jax.value_and_grad(loss)(params)
    with attention.flash_vjp(True):
        l_on, g_on = jax.value_and_grad(loss)(params)
    assert float(jnp.abs(l_on - l_off)) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(g_off), jax.tree_util.tree_leaves(g_on)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
