import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.omd import OMDConfig, OnlineMirrorDescent, alpha_schedule
from repro.optim import adamw, apply_updates, constant, cosine, sgd, warmup_cosine, wsd


def _quadratic_losses(opt, steps=200, lr_used=None):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    loss_fn = lambda p: jnp.sum(jnp.square(p["w"] - target))
    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss_fn(params))


def test_sgd_converges_quadratic():
    assert _quadratic_losses(sgd(constant(0.1))) < 1e-3


def test_sgd_momentum_converges():
    assert _quadratic_losses(sgd(constant(0.05), momentum=0.9)) < 1e-3


def test_adamw_converges_quadratic():
    assert _quadratic_losses(adamw(constant(0.05), weight_decay=0.0)) < 1e-2


def test_adamw_weight_decay_shrinks():
    opt = adamw(constant(0.1), weight_decay=1.0)
    params = {"w": jnp.full((3,), 10.0)}
    state = opt.init(params)
    upd, state = opt.update({"w": jnp.zeros(3)}, state, params)
    assert float(apply_updates(params, upd)["w"][0]) < 10.0


def test_schedules_shapes():
    assert float(constant(0.1)(jnp.asarray(1000))) == pytest.approx(0.1)
    cs = cosine(1.0, 100)
    assert float(cs(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cs(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)


def test_wsd_phases():
    f = wsd(1.0, warmup=10, stable=50, decay=40, final_frac=0.1)
    assert float(f(jnp.asarray(5))) == pytest.approx(0.5)      # warmup
    assert float(f(jnp.asarray(30))) == pytest.approx(1.0)     # stable
    assert float(f(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)  # decayed
    # decay is monotone
    vals = [float(f(jnp.asarray(60 + i))) for i in range(0, 41, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_alpha_schedules():
    s = alpha_schedule("sqrt_t", 1.0)
    assert float(s(jnp.asarray(4))) == pytest.approx(0.5)
    t2 = alpha_schedule("theorem2", 1.0, T=100)
    assert float(t2(jnp.asarray(1))) == float(t2(jnp.asarray(99))) == pytest.approx(0.1)
    with pytest.raises(ValueError):
        alpha_schedule("theorem2", 1.0)  # needs T


def test_omd_equals_sgd_when_no_prox():
    """phi = 1/2||.||^2, lam = 0 => OMD is plain (noise-free, mix-free) SGD."""
    cfg = OMDConfig(alpha0=0.1, schedule="constant", lam=0.0, prox_kind="none")
    omd = OnlineMirrorDescent(cfg)
    params = {"w": jnp.array([1.0, 2.0])}
    state = omd.init(params)
    g = {"w": jnp.array([0.5, -0.5])}
    state = omd.dual_step(state, state.theta, g)
    w = omd.primal(state)
    np.testing.assert_allclose(np.asarray(w["w"]), [0.95, 2.05], rtol=1e-6)


def test_omd_prox_sparsifies():
    cfg = OMDConfig(alpha0=1.0, schedule="constant", lam=0.5, prox_kind="l1")
    omd = OnlineMirrorDescent(cfg)
    state = omd.init({"w": jnp.array([0.3, -0.2, 2.0])})
    w = omd.primal(state)
    np.testing.assert_allclose(np.asarray(w["w"]), [0.0, 0.0, 1.5], atol=1e-6)
