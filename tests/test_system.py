"""End-to-end behaviour tests for the whole system (train + serve drivers)."""
import jax
import numpy as np
import pytest

from repro.launch.serve_lm import serve
from repro.launch.train import train


def test_gossip_training_end_to_end_loss_decreases():
    res = train("minicpm-2b", strategy="gossip", nodes=4, steps=12,
                batch_per_node=2, seq_len=64, eps=float("inf"), lam=1e-5,
                smoke=True)
    losses = [h["ce"] for h in res["history"]]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_private_gossip_training_runs_and_is_noisier():
    res_p = train("minicpm-2b", strategy="gossip", nodes=4, steps=8,
                  batch_per_node=2, seq_len=64, eps=0.5, smoke=True, seed=1)
    assert all(np.isfinite(h["loss"]) for h in res_p["history"])
    assert res_p["history"][0]["noise_scale"] > 0


def test_allreduce_baseline_end_to_end():
    res = train("qwen2-7b", strategy="allreduce", steps=10, batch_per_node=4,
                seq_len=64, smoke=True)
    losses = [h["ce"] for h in res["history"]]
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_serve_end_to_end():
    out = serve("qwen2-7b", batch=2, prompt_len=8, gen=4, cache_len=32, smoke=True)
    # collected tokens = first prompt token + `gen` generated ones
    assert out["tokens"].shape == (2, 1 + 4)
    assert (out["tokens"] >= 0).all()


def test_serve_ssm_arch():
    out = serve("rwkv6-3b", batch=2, prompt_len=8, gen=4, cache_len=32, smoke=True)
    assert out["tokens"].shape[0] == 2
