"""Dense-vs-sparse equivalence — the correctness anchor for the sparse path.

SparseGraph / SparseMixer must be *dense-equivalent*: the same topology
expressed as an edge list and driven through `segment_sum` has to reproduce
the dense n x n matvec exactly where the arithmetic is identical (edge
construction, conversions, duplicate merging) and within an ASSERTED
float32 reduction-order bound where it is not (segment_sum may sum a row in
a different order than tensordot/roll). Full-run tolerances below are the
contract `repro.api.shard_node` inherits; tests/test_shard_node.py extends
them across devices.
"""
import numpy as np
import pytest

from repro.api import RunSpec, run
from repro.api.mixers import MIXERS, DelayedMixer, RingRollMixer, SparseMixer
from repro.core.graph import (
    GossipGraph, SparseGraph, ring_edges, ring_matrix, torus_edges,
    torus_matrix,
)

# float32 reduction-order bound for whole-run trajectories at these sizes;
# the suite asserts it explicitly (acceptance: "tolerance-bounded with the
# bound asserted")
RUN_ATOL = 2e-6


def _spec(**kw):
    base = dict(nodes=10, dim=8, horizon=14, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)


# -- graph construction / conversions ----------------------------------------

def test_ring_edges_match_dense_ring_exactly():
    for m, sw in [(1, 0.5), (2, 0.5), (3, 0.25), (8, 0.5), (17, 0.8)]:
        g = ring_edges(m, self_weight=sw)
        np.testing.assert_array_equal(g.to_dense(), ring_matrix(m, sw))


def test_torus_edges_match_dense_torus_exactly():
    for rows, cols in [(2, 2), (3, 4), (4, 4)]:
        g = torus_edges(rows, cols)
        np.testing.assert_array_equal(g.to_dense(), torus_matrix(rows, cols))


@pytest.mark.parametrize("topology,m", [
    ("ring", 12), ("torus", 16), ("hypercube", 16), ("random", 12),
    ("complete", 9), ("disconnected", 5),
])
def test_from_dense_round_trips_bit_exactly(topology, m):
    A = np.asarray(GossipGraph.make(topology, m, seed=3).at(0), np.float32)
    g = SparseGraph.from_dense(A, name=topology)
    np.testing.assert_array_equal(g.to_dense(), A)
    # CSR view is consistent with the canonical (dst, src) sort
    indptr = g.indptr
    assert indptr[0] == 0 and indptr[-1] == g.edges
    np.testing.assert_array_equal(np.diff(indptr), g.degree())


@pytest.mark.parametrize("topology,m", [("ring", 10), ("torus", 16),
                                        ("hypercube", 8), ("random", 12)])
def test_sparse_make_validates_and_matches_dense(topology, m):
    g = SparseGraph.make(topology, m, seed=1)
    A = np.asarray(GossipGraph.make(topology, m, seed=1).at(0), np.float32)
    np.testing.assert_allclose(g.to_dense(), A, atol=1e-7)
    assert g.validate() is g


def test_sparse_make_scales_without_dense_materialization():
    g = SparseGraph.make("ring", 100_000)
    assert g.m == 100_000 and g.edges == 300_000
    assert float(g.diag()[0]) == 0.5


def test_time_varying_has_no_sparse_form():
    with pytest.raises(ValueError, match="time_varying|sparse"):
        SparseGraph.make("time_varying", 8)


# -- segment_sum edge cases: self-loops, duplicates, isolated nodes ----------

def test_duplicate_edges_merge_dense_equivalently():
    """Repeated (dst, src) entries sum like the dense += — pinned to bits."""
    dst = np.array([0, 0, 1, 1, 0], np.int64)
    src = np.array([1, 1, 0, 1, 0], np.int64)
    w = np.array([0.25, 0.25, 0.5, 0.5, 0.5], np.float32)
    g = SparseGraph(dst=dst, src=src, weight=w, m=2)
    dense = np.zeros((2, 2), np.float32)
    np.add.at(dense, (dst, src), w)
    np.testing.assert_array_equal(g.to_dense(), dense)
    assert g.edges == 4                       # the duplicate collapsed
    g.validate()                              # still doubly stochastic


def test_self_loops_are_the_diagonal():
    g = ring_edges(6, self_weight=0.4)
    np.testing.assert_allclose(g.diag(), np.full(6, 0.4, np.float32))
    # a graph without self-loops has a zero diagonal, not an error
    perm = SparseGraph(dst=np.arange(4), src=(np.arange(4) + 1) % 4,
                       weight=np.ones(4, np.float32), m=4)
    np.testing.assert_array_equal(perm.diag(), np.zeros(4, np.float32))
    perm.validate()                           # permutation: doubly stochastic


def test_isolated_node_rejected_with_clear_error():
    """A zero-degree node makes its row sum 0; validate() names it."""
    g = SparseGraph(dst=np.array([0, 1]), src=np.array([1, 0]),
                    weight=np.ones(2, np.float32), m=3)   # node 2 isolated
    with pytest.raises(ValueError, match="isolated|rows"):
        g.validate()
    # ...but the aggregation itself is still dense-equivalent: row 2 -> 0
    mixer = SparseMixer(graph=g)
    import jax.numpy as jnp
    x = jnp.arange(3.0)[:, None]
    out = np.asarray(mixer.apply(x, 0))
    np.testing.assert_allclose(out, g.to_dense() @ np.arange(3.0)[:, None],
                               atol=1e-6)
    assert out[2, 0] == 0.0


def test_out_of_range_edges_rejected():
    with pytest.raises(ValueError, match="out of range"):
        SparseGraph(dst=np.array([0, 3]), src=np.array([0, 0]),
                    weight=np.ones(2, np.float32), m=3)
    with pytest.raises(ValueError, match="m must be"):
        SparseGraph(dst=np.zeros(0, np.int64), src=np.zeros(0, np.int64),
                    weight=np.zeros(0, np.float32), m=0)


def test_negative_and_sub_eta_weights_rejected():
    g = SparseGraph(dst=np.array([0, 0, 1, 1]), src=np.array([0, 1, 0, 1]),
                    weight=np.array([1.5, -0.5, -0.5, 1.5], np.float32), m=2)
    with pytest.raises(ValueError, match="negative"):
        g.validate()
    h = SparseGraph(dst=np.array([0, 0, 1, 1]), src=np.array([0, 1, 0, 1]),
                    weight=np.array([1 - 1e-8, 1e-8, 1e-8, 1 - 1e-8],
                                    np.float32), m=2)
    with pytest.raises(ValueError, match="eta"):
        h.validate(eta=1e-3, atol=1e-9)


def test_symmetry_check():
    assert ring_edges(8).is_symmetric()
    assert SparseGraph.make("hypercube", 8).is_symmetric(atol=1e-7)
    asym = SparseGraph(dst=np.array([0, 1]), src=np.array([1, 0]),
                       weight=np.array([0.3, 0.7], np.float32), m=2)
    assert not asym.is_symmetric()


# -- SparseMixer vs dense mixers ---------------------------------------------

def test_sparse_mixer_needs_a_sparse_graph():
    with pytest.raises(TypeError, match="SparseGraph"):
        SparseMixer(graph=np.eye(4))


@pytest.mark.parametrize("topology,m", [("ring", 9), ("torus", 16),
                                        ("hypercube", 16), ("random", 12)])
def test_sparse_apply_matches_dense_matvec(topology, m):
    import jax.numpy as jnp
    mixer = MIXERS.build("sparse", m=m, topology=topology, seed=2)
    A = np.asarray(mixer.graph.to_dense())
    x = np.random.default_rng(0).normal(size=(m, 5)).astype(np.float32)
    out = np.asarray(mixer.apply(jnp.asarray(x), 0))
    ref = A @ x
    bound = 1e-6
    assert np.abs(out - ref).max() <= bound, (topology, np.abs(out - ref).max())


def test_registry_builds_sparse_from_prebuilt_graph_and_delay():
    g = ring_edges(6)
    mixer = MIXERS.build("sparse", m=6, graph=g)
    assert isinstance(mixer, SparseMixer) and mixer.name == "ring"
    resolved = _spec(nodes=6, delay=2).resolve_mixer()
    assert isinstance(resolved, DelayedMixer) and resolved.delay == 2
    assert isinstance(resolved.inner, SparseMixer)


# -- full-run equivalence: sparse vs dense, both engines, delay, noise on ----

@pytest.mark.parametrize("engine", ["sim", "dist"])
@pytest.mark.parametrize("delay", [0, 2])
def test_run_sparse_matches_dense_ring(engine, delay):
    """run(mixer='sparse') vs run(mixer='ring'): same topology, Laplace
    noise ON — every trajectory within the asserted reduction-order bound."""
    dense = run(_spec(mixer="ring", mixer_options={}, delay=delay),
                engine=engine, chunk_rounds=7, warmup=False,
                compute_regret=False)
    sparse = run(_spec(delay=delay), engine=engine, chunk_rounds=7,
                 warmup=False, compute_regret=False)
    for f in ("final_w", "loss", "w_bar_loss", "sparsity", "correct"):
        a, b = np.asarray(getattr(dense, f)), np.asarray(getattr(sparse, f))
        assert np.abs(a - b).max() <= RUN_ATOL, \
            f"{engine}/delay={delay}: {f} off by {np.abs(a - b).max()}"
    np.testing.assert_array_equal(dense.eps_ledger, sparse.eps_ledger)


@pytest.mark.parametrize("delay", [0, 2])
def test_sparse_sim_vs_dist_bit_identical(delay):
    """The cross-engine bit-identity contract extends to the sparse mixer."""
    sim = run(_spec(delay=delay), engine="sim", chunk_rounds=7,
              warmup=False, compute_regret=False)
    dist = run(_spec(delay=delay), engine="dist", chunk_rounds=7,
               warmup=False, compute_regret=False)
    np.testing.assert_array_equal(sim.final_w, dist.final_w)
    np.testing.assert_array_equal(np.asarray(sim.loss),
                                  np.asarray(dist.loss))


def test_run_sparse_torus_matches_dense_torus():
    dense = run(_spec(mixer="torus", mixer_options={}, nodes=16),
                chunk_rounds=7, warmup=False, compute_regret=False)
    sparse = run(_spec(nodes=16, mixer_options={"topology": "torus"}),
                 chunk_rounds=7, warmup=False, compute_regret=False)
    assert np.abs(dense.final_w - sparse.final_w).max() <= RUN_ATOL


def test_sparse_checkpoint_resume_bit_identical(tmp_path):
    sp = _spec(delay=1, horizon=12)
    full = run(sp, chunk_rounds=6, warmup=False, compute_regret=False)
    ck = str(tmp_path / "ck")
    run(sp, chunk_rounds=6, warmup=False, compute_regret=False,
        checkpoint_every=6, checkpoint_dir=ck, horizon=6)
    resumed = run(sp, chunk_rounds=6, warmup=False, compute_regret=False,
                  checkpoint_dir=ck, resume=True)
    assert resumed.start_round == 6
    np.testing.assert_array_equal(full.final_w, resumed.final_w)
