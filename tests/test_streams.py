"""STREAMS registry + the four built-in data scenarios."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import STREAMS, RunSpec
from repro.api.registry import UnknownEntryError
from repro.api.streams import (BurstyStream, DriftStream,
                               HeterogeneousStream, SocialStream, Stream)
from repro.data.social import labels_from_logits


ALL = ("social_sparse", "drift", "heterogeneous", "bursty")


def test_all_four_scenarios_registered():
    assert set(ALL) <= set(STREAMS.names())


@pytest.mark.parametrize("name", ALL)
def test_stream_protocol_shapes_and_labels(name):
    s = STREAMS.build(name, n=32, nodes=4, rounds=20, seed=5)
    assert isinstance(s, Stream)
    assert s.disjoint  # Theorem-1 parallel composition condition
    xs, ys = s.chunk(0, 20)
    assert xs.shape == (20, 4, 32) and ys.shape == (20, 4)
    assert xs.dtype == jnp.float32 and ys.dtype == jnp.float32
    # labels are strictly ±1 — never the invalid 0
    assert set(np.unique(np.asarray(ys))) <= {-1.0, 1.0}


@pytest.mark.parametrize("name", ALL)
def test_stream_chunk_boundary_invariance(name):
    """Round t's data never depends on how the horizon is chunked — the
    property checkpoint resume and run()'s chunking rely on."""
    s = STREAMS.build(name, n=16, nodes=3, rounds=30, seed=2)
    xs_whole, ys_whole = s.chunk(0, 30)
    xs_a, ys_a = s.chunk(0, 7)
    xs_b, ys_b = s.chunk(7, 30)
    np.testing.assert_array_equal(np.asarray(xs_whole),
                                  np.concatenate([xs_a, xs_b]))
    np.testing.assert_array_equal(np.asarray(ys_whole),
                                  np.concatenate([ys_a, ys_b]))


def test_labels_from_logits_zero_maps_to_plus_one():
    """Regression: jnp.sign(logits + 1e-12) returned y == 0 for logits of
    exactly -1e-12; the label rule is now y = +1 iff logit >= 0."""
    logits = jnp.asarray([0.0, -0.0, 1e-30, -1e-12, 2.0, -3.0])
    y = labels_from_logits(logits)
    np.testing.assert_array_equal(np.asarray(y), [1, 1, 1, -1, 1, -1])
    assert not np.any(np.asarray(y) == 0.0)


def test_social_all_zero_ground_truth_still_emits_valid_labels():
    # sparsity_true=0 gives w* = 0 => every logit is exactly 0
    s = SocialStream(n=16, nodes=2, rounds=4, sparsity_true=0.0, seed=0)
    _, ys = s.chunk(0, 4)
    np.testing.assert_array_equal(np.asarray(ys), 1.0)


def test_social_w_true_cached_across_chunks():
    """Satellite fix: w* used to be recomputed per chunk() call."""
    a = SocialStream(n=64, nodes=4, rounds=100, seed=3)
    b = SocialStream(n=64, nodes=4, rounds=50, seed=3)  # rounds irrelevant
    assert a.w_true() is a.w_true()
    assert a.w_true() is b.w_true()
    assert a.w_true() is not SocialStream(n=64, nodes=4, rounds=100,
                                          seed=4).w_true()


def test_drift_ground_truth_changes_across_phases():
    s = DriftStream(n=64, nodes=2, rounds=128, period=16, seed=0)
    w0 = np.asarray(s.w_true_at(0))
    w_same = np.asarray(s.w_true_at(15))   # same phase
    w_next = np.asarray(s.w_true_at(16))   # next phase
    np.testing.assert_array_equal(w0, w_same)
    assert not np.array_equal(w0, w_next)
    # labels in a chunk follow the CURRENT phase's w*
    xs, ys = s.chunk(16, 20)
    np.testing.assert_array_equal(
        np.asarray(labels_from_logits(jnp.einsum("n,tmn->tm",
                                                 jnp.asarray(w_next), xs))),
        np.asarray(ys))


def test_drift_rotate_mode_preserves_support_size():
    s = DriftStream(n=64, nodes=2, rounds=64, period=8, mode="rotate", seed=1)
    w0, w1 = np.asarray(s.w_true_at(0)), np.asarray(s.w_true_at(8))
    assert not np.array_equal(w0, w1)
    assert (w0 != 0).sum() == (w1 != 0).sum()        # rolled, not redrawn
    np.testing.assert_allclose(np.sort(np.abs(w0)), np.sort(np.abs(w1)),
                               rtol=1e-6)


def test_heterogeneous_nodes_differ():
    s = HeterogeneousStream(n=32, nodes=8, rounds=64, scale_spread=0.8,
                            noise_max=0.3, seed=0)
    scales = np.asarray(s.node_scales())
    rates = np.asarray(s.node_noise_rates())
    assert scales.shape == rates.shape == (8,)
    assert scales.std() > 0 and (scales > 0).all()
    assert (rates >= 0).all() and (rates < 0.3).all() and rates.std() > 0
    # per-node feature magnitudes actually follow the drawn scales
    xs, _ = s.chunk(0, 64)
    emp = np.asarray(xs).std(axis=(0, 2)) * np.sqrt(32)
    np.testing.assert_allclose(emp, scales, rtol=0.15)


def test_bursty_counts_heavy_tailed_and_bounded():
    s = BurstyStream(n=16, nodes=4, rounds=256, burst_max=8, tail=1.2, seed=0)
    c = np.asarray(s.counts(0, 256))
    assert c.min() >= 1 and c.max() <= 8
    assert c.max() > 1                     # the tail actually fires
    assert 1.0 < c.mean() < 4.0            # heavy-tailed, not degenerate
    # busier rounds carry lower-variance (smaller-norm) mean samples
    xs, _ = s.chunk(0, 256)
    norms = np.linalg.norm(np.asarray(xs), axis=2)
    lo, hi = norms[c == 1].mean(), norms[c >= 4].mean()
    assert hi < lo


def test_runspec_resolves_stream_by_name_and_instance():
    spec = RunSpec(nodes=4, dim=32, horizon=16, stream="drift",
                   stream_options={"period": 4})
    s = spec.resolve_stream()
    assert isinstance(s, DriftStream) and s.period == 4
    assert (s.n, s.nodes, s.rounds) == (32, 4, 16)
    inst = SocialStream(n=32, nodes=4, rounds=16)
    assert spec.replace(stream=inst).resolve_stream() is inst


def test_runspec_stream_validation():
    with pytest.raises(UnknownEntryError):
        RunSpec(nodes=4, dim=8, horizon=8, stream="nope").resolve_stream()
    with pytest.raises(ValueError):  # horizon required for named streams
        RunSpec(nodes=4, dim=8, stream="drift").resolve_stream()
    with pytest.raises(TypeError):   # typo'd option must not pass silently
        RunSpec(nodes=4, dim=8, horizon=8, stream="drift",
                stream_options={"perriod": 4}).resolve_stream()
    with pytest.raises(ValueError):  # instance/node-count mismatch
        RunSpec(nodes=8, dim=32,
                stream=SocialStream(n=32, nodes=4, rounds=8)).resolve_stream()


def test_stream_instances_are_frozen_and_hashable():
    # run()'s comparator cache keys on the stream instance itself
    a = DriftStream(n=8, nodes=2, rounds=4)
    assert hash(a) == hash(DriftStream(n=8, nodes=2, rounds=4))
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.period = 3


def test_bursty_counts_chunk_invariant():
    # the arrival process is keyed per ABSOLUTE round: any chunking of
    # [0, T) reproduces the same burst sizes, so a replay client and the
    # training stream agree on the workload no matter the chunk size
    s = BurstyStream(n=8, nodes=4, rounds=64, seed=5)
    whole = np.asarray(s.counts(0, 64))
    for step in (1, 8, 24):
        parts = [np.asarray(s.counts(a, min(a + step, 64)))
                 for a in range(0, 64, step)]
        np.testing.assert_array_equal(np.concatenate(parts), whole)
    # and it is deterministic per seed, distinct across seeds
    np.testing.assert_array_equal(
        whole, np.asarray(BurstyStream(n=8, nodes=4, rounds=64,
                                       seed=5).counts(0, 64)))
    assert (np.asarray(BurstyStream(n=8, nodes=4, rounds=64,
                                    seed=6).counts(0, 64)) != whole).any()


def test_bursty_counts_match_pareto_tail():
    # P(c >= k) ~ k^-tail below the cap: the empirical CCDF of the drawn
    # counts must track the discrete-Pareto law they claim to follow
    tail, cap = 1.5, 64
    s = BurstyStream(n=4, nodes=16, rounds=2048, burst_max=cap, tail=tail,
                     seed=1)
    c = np.asarray(s.counts(0, 2048)).ravel()
    assert c.min() >= 1 and c.max() <= cap
    for k in (2, 4, 8):
        emp = (c >= k).mean()
        expect = float(k) ** -tail       # P(floor(u^-1/tail) >= k)
        assert abs(emp - expect) < 0.25 * expect + 0.01, (k, emp, expect)
    # burstiness: the index of dispersion of per-round totals exceeds
    # Poisson's (=1) — arrivals cluster instead of smoothing out
    totals = np.asarray(s.counts(0, 2048)).sum(axis=1)
    assert totals.var() / totals.mean() > 1.0
