"""Delayed-gossip extension (the paper's stated future work)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Algorithm1, GossipGraph, OMDConfig, PrivacyConfig
from repro.data.social import SocialStream


def _alg(delay, m=8, n=64):
    return Algorithm1(
        graph=GossipGraph.make("ring", m),
        omd=OMDConfig(alpha0=1.0, schedule="sqrt_t", lam=0.01),
        privacy=PrivacyConfig(eps=math.inf, L=1.0),
        n=n, delay=delay,
    )


def _stream(m=8, n=64, T=250):
    s = SocialStream(n=n, nodes=m, rounds=T, sparsity_true=0.2, seed=4)
    return s.chunk(0, T)


def test_delay_zero_unchanged():
    """delay=0 must be bit-identical to the original algorithm."""
    xs, ys = _stream()
    base = Algorithm1(graph=GossipGraph.make("ring", 8),
                      omd=OMDConfig(alpha0=1.0, schedule="sqrt_t", lam=0.01),
                      privacy=PrivacyConfig(eps=math.inf, L=1.0), n=64)
    a = base.run(jax.random.PRNGKey(0), xs, ys)
    b = _alg(0).run(jax.random.PRNGKey(0), xs, ys)
    np.testing.assert_array_equal(np.asarray(a.loss), np.asarray(b.loss))


def test_delayed_still_learns():
    xs, ys = _stream()
    outs = _alg(4).run(jax.random.PRNGKey(0), xs, ys)
    assert float(outs.correct[-80:].mean()) > 0.7


def test_large_delay_degrades_but_no_divergence():
    xs, ys = _stream()
    fast = _alg(0).run(jax.random.PRNGKey(0), xs, ys)
    slow = _alg(32).run(jax.random.PRNGKey(0), xs, ys)
    assert np.isfinite(np.asarray(slow.loss)).all()
    assert float(slow.correct[-80:].mean()) <= float(fast.correct[-80:].mean()) + 0.05


def test_negative_delay_rejected():
    with pytest.raises(ValueError):
        _alg(-1)
