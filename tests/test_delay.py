"""Delayed-gossip extension (the paper's stated future work)."""
import math

import jax
import numpy as np
import pytest

from repro.api import RunSpec
from repro.data.social import SocialStream


def _spec(delay, m=8, n=64):
    return RunSpec(nodes=m, dim=n, mixer="ring", mechanism="laplace",
                   eps=math.inf, clip_norm=1.0, calibration="global",
                   alpha0=1.0, schedule="sqrt_t", lam=0.01, delay=delay)


def _alg(delay, m=8, n=64):
    return _spec(delay, m, n).build_simulator()


def _stream(m=8, n=64, T=250):
    s = SocialStream(n=n, nodes=m, rounds=T, sparsity_true=0.2, seed=4)
    return s.chunk(0, T)


def test_delay_zero_unchanged():
    """delay=0 must be bit-identical to the original algorithm."""
    xs, ys = _stream()
    a = _spec(0).build_simulator().run(jax.random.PRNGKey(0), xs, ys)
    b = _alg(0).run(jax.random.PRNGKey(0), xs, ys)
    np.testing.assert_array_equal(np.asarray(a.loss), np.asarray(b.loss))


def test_delayed_still_learns():
    xs, ys = _stream()
    outs = _alg(4).run(jax.random.PRNGKey(0), xs, ys)
    assert float(outs.correct[-80:].mean()) > 0.7


def test_large_delay_degrades_but_no_divergence():
    xs, ys = _stream()
    fast = _alg(0).run(jax.random.PRNGKey(0), xs, ys)
    slow = _alg(32).run(jax.random.PRNGKey(0), xs, ys)
    assert np.isfinite(np.asarray(slow.loss)).all()
    assert float(slow.correct[-80:].mean()) <= float(fast.correct[-80:].mean()) + 0.05


def test_heterogeneous_delay_still_learns():
    """Per-edge delays (seeded distribution) keep the learner convergent."""
    xs, ys = _stream()
    alg = _spec(4).replace(delay_dist="uniform").build_simulator()
    outs = alg.run(jax.random.PRNGKey(0), xs, ys)
    assert float(outs.correct[-80:].mean()) > 0.7


def test_negative_delay_rejected():
    from repro.api import LaplaceMechanism, RingRollMixer
    from repro.core import Algorithm1, OMDConfig

    with pytest.raises(ValueError):
        Algorithm1(omd=OMDConfig(), n=64, mixer=RingRollMixer(m=8),
                   mechanism=LaplaceMechanism(), delay=-1)
